"""Markdown postmortems and run-vs-run diffs from trace streams.

Both renderers take *only* the recorded JSONL rows (header + events) —
no engine internals, no live objects — so they work identically on a
live run's stream, a crash artifact re-read with ``strict=False``, or a
trace copied off another machine:

  * `postmortem_md(rows)`  — one run's story: header, fleet summary,
    incident timeline, top-k stragglers, SLO compliance (time in
    incident vs run extent), detection confusion (the Fig. 6 quality
    numbers, reconstructed from the ``detect.verdict`` audit log against
    the ``fleet.population`` ground truth), and the sim-event timeline;
  * `run_diff_md(rows_a, rows_b)` — two runs side by side: metric
    deltas with direction-aware regression verdicts (accuracy falling is
    a regression, bytes falling is an improvement).

`tools/obs_report.py` is the CLI wrapper.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

from .analysis import FleetAnalytics
from .events import TraceEvent

_EVENT_KINDS = ("span", "instant", "counter")


def _split(rows: Iterable[Dict[str, Any]]
           ) -> Tuple[Dict[str, Any], List[TraceEvent]]:
    """Rows -> (header, events); tolerates interleaved non-event rows
    (metrics snapshots, report footers)."""
    header: Dict[str, Any] = {}
    events: List[TraceEvent] = []
    for row in rows:
        kind = row.get("kind")
        if kind == "header":
            header = row
        elif kind in _EVENT_KINDS:
            events.append(TraceEvent.from_dict(row))
    return header, events


def analyze(rows: Iterable[Dict[str, Any]]
            ) -> Tuple[Dict[str, Any], FleetAnalytics]:
    header, events = _split(rows)
    return header, FleetAnalytics.from_events(events)


# ---------------------------------------------------------------------------
# formatting primitives
# ---------------------------------------------------------------------------

def _fmt(v: Any) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        if v != v:                       # NaN
            return "—"
        if abs(v) >= 1000 or (v != 0 and abs(v) < 0.01):
            return f"{v:.3g}"
        return f"{v:.3f}".rstrip("0").rstrip(".")
    return str(v)


def _fmt_bytes(v: Optional[float]) -> str:
    if v is None:
        return "—"
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(v) < 1024 or unit == "GiB":
            return (f"{v:.0f} {unit}" if unit == "B"
                    else f"{v:.2f} {unit}")
        v /= 1024
    return f"{v:.2f} GiB"


def _table(headers: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    out += ["| " + " | ".join(r) + " |" for r in rows]
    return out


# ---------------------------------------------------------------------------
# postmortem
# ---------------------------------------------------------------------------

def postmortem_md(rows: Iterable[Dict[str, Any]], top_k: int = 5) -> str:
    """Render one trace stream as a Markdown postmortem."""
    header, an = analyze(rows)
    snap = an.snapshot()
    lines: List[str] = ["# Fleet postmortem", ""]

    # -- run header
    meta = {k: v for k, v in header.items()
            if k not in ("kind",)} if header else {}
    if meta:
        lines += _table(["run", "value"],
                        [[k, _fmt(v)] for k, v in sorted(meta.items())])
        lines.append("")

    # -- run summary
    t0, t1 = snap["virtual_extent"]
    extent = (t1 - t0) if (t0 is not None and t1 is not None) else None
    lines += ["## Run summary", ""]
    lines += _table(["indicator", "value"], [
        ["fleet size", _fmt(snap["n_nodes"])],
        ["nodes seen", _fmt(snap["nodes_seen"])],
        ["virtual extent", f"{_fmt(extent)} s"],
        ["records (windows / rounds)",
         f"{snap['n_windows']} / {snap['n_rounds']}"],
        ["recent occupancy", _fmt(snap["occupancy_recent"])],
        ["window skew (max/median)", _fmt(snap["window_skew"])],
        ["upload bytes", _fmt_bytes(snap["total_upload_bytes"])],
        ["uploads / retransmits",
         f"{snap['total_uploads']} / {snap['total_retransmits']}"],
        ["final accuracy", _fmt(snap["final_accuracy"])],
    ])
    lines.append("")

    # -- incident timeline
    lines += ["## Incidents", ""]
    if an.incidents:
        rows_ = []
        for inc in sorted(an.incidents,
                          key=lambda i: (i.get("t") or 0.0,
                                         str(i.get("probe")))):
            subject = (f"node {inc['node']}" if "node" in inc else "fleet")
            rows_.append([
                _fmt(inc.get("t")), _fmt(inc.get("duration")),
                str(inc.get("probe")), subject, _fmt(inc.get("worst")),
                _fmt(inc.get("threshold")),
                "resolved" if inc.get("resolved") else "open at run end"])
        lines += _table(["opened (t)", "duration (s)", "probe", "subject",
                         "worst", "threshold", "state"], rows_)
    else:
        lines.append("No incidents recorded "
                     "(health probes off or nothing fired).")
    lines.append("")

    # -- SLO compliance: virtual time NOT in incident, per probe
    if an.incidents and extent and extent > 0:
        lines += ["## SLO compliance", ""]
        by_probe: Dict[str, float] = {}
        counts: Dict[str, int] = {}
        for inc in an.incidents:
            p = str(inc.get("probe"))
            by_probe[p] = by_probe.get(p, 0.0) + (inc.get("duration")
                                                  or 0.0)
            counts[p] = counts.get(p, 0) + 1
        rows_ = [[p, str(counts[p]), _fmt(by_probe[p]),
                  _fmt(max(0.0, 1.0 - by_probe[p] / extent))]
                 for p in sorted(by_probe)]
        lines += _table(["probe", "incidents", "time in incident (s)",
                         "compliance"], rows_)
        lines.append("")

    # -- top-k stragglers
    stragglers = an.top_stragglers(k=top_k)
    lines += [f"## Top {top_k} stragglers", ""]
    if stragglers:
        lines += _table(
            ["node", "score (× median gap)", "arrivals", "mean gap (s)",
             "bytes"],
            [[str(s["node"]), _fmt(s["score"]), str(s["arrivals"]),
              _fmt(s["mean_gap"]), _fmt_bytes(s["bytes"])]
             for s in stragglers])
    else:
        lines.append("No arrival cadence recorded "
                     "(sync schedule, or too few arrivals).")
    lines.append("")

    # -- detection quality (Fig. 6 reconstruction)
    det = snap["detection"]
    lines += ["## Detection quality", ""]
    if snap["n_verdicts"]:
        rows_ = [
            ["verdicts audited", str(snap["n_verdicts"])],
            ["reject rate", _fmt(snap["reject_rate"])],
            ["threshold drift", _fmt(snap["threshold_drift"])],
        ]
        if det["ground_truth"]:
            rows_ += [
                ["true positives (malicious rejected)", str(det["tp"])],
                ["false positives (honest rejected)", str(det["fp"])],
                ["true negatives (honest accepted)", str(det["tn"])],
                ["false negatives (malicious accepted)", str(det["fn"])],
                ["precision", _fmt(det["precision"])],
                ["recall", _fmt(det["recall"])],
                ["accuracy", _fmt(det["accuracy"])],
            ]
        lines += _table(["metric", "value"], rows_)
        if not det["ground_truth"]:
            lines += ["", "_No `fleet.population` ground truth in this "
                          "trace — confusion matrix unavailable._"]
    else:
        lines.append("No armed detection verdicts in this trace.")
    lines.append("")

    # -- sim-event timeline
    if an.sim_events:
        lines += ["## Simulation events", ""]
        lines += _table(
            ["t", "record", "kind"],
            [[_fmt(e.get("t")), _fmt(e.get("at_round")),
              str(e.get("kind", "?"))]
             for e in an.sim_events])
        lines.append("")

    return "\n".join(lines).rstrip() + "\n"


# ---------------------------------------------------------------------------
# run-vs-run diff
# ---------------------------------------------------------------------------

# (label, snapshot key, higher_is_better or None for neutral)
_DIFF_METRICS: List[Tuple[str, str, Optional[bool]]] = [
    ("final accuracy", "final_accuracy", True),
    ("upload bytes", "total_upload_bytes", False),
    ("uploads", "total_uploads", None),
    ("retransmits", "total_retransmits", False),
    ("reject rate", "reject_rate", None),
    ("recent occupancy", "occupancy_recent", True),
    ("window skew", "window_skew", False),
    ("windows", "n_windows", None),
    ("rounds", "n_rounds", None),
    ("verdicts", "n_verdicts", None),
    ("incidents", "n_incidents", False),
    ("alerts", "n_alerts", False),
]


def run_diff_md(rows_a: Iterable[Dict[str, Any]],
                rows_b: Iterable[Dict[str, Any]],
                label_a: str = "A", label_b: str = "B",
                rtol: float = 0.05) -> Tuple[str, int]:
    """Render a run-vs-run Markdown diff.  Returns ``(markdown,
    n_regressions)`` — a regression is a direction-aware metric moving
    the wrong way by more than ``rtol`` relative (or appearing/growing
    from zero)."""
    _, an_a = analyze(rows_a)
    _, an_b = analyze(rows_b)
    snap_a, snap_b = an_a.snapshot(), an_b.snapshot()

    rows_: List[List[str]] = []
    n_reg = 0
    for label, key, higher_better in _DIFF_METRICS:
        va, vb = snap_a.get(key), snap_b.get(key)
        verdict, is_reg = _verdict(va, vb, higher_better, rtol)
        n_reg += is_reg
        fmt = _fmt_bytes if key == "total_upload_bytes" else _fmt
        rows_.append([label, fmt(va), fmt(vb), verdict])

    det_a = snap_a["detection"]
    det_b = snap_b["detection"]
    if det_a["ground_truth"] and det_b["ground_truth"]:
        for label, key in (("detection precision", "precision"),
                           ("detection recall", "recall"),
                           ("detection accuracy", "accuracy")):
            va, vb = det_a.get(key), det_b.get(key)
            verdict, is_reg = _verdict(va, vb, True, rtol)
            n_reg += is_reg
            rows_.append([label, _fmt(va), _fmt(vb), verdict])

    lines = [f"# Run diff: {label_a} vs {label_b}", ""]
    lines += _table(["metric", label_a, label_b, "verdict"], rows_)
    lines += ["", f"**{n_reg} regression(s).**" if n_reg
              else "**No regressions.**"]
    return "\n".join(lines) + "\n", n_reg


def _verdict(va: Any, vb: Any, higher_better: Optional[bool],
             rtol: float) -> Tuple[str, bool]:
    """One metric's verdict comparing baseline ``va`` to candidate
    ``vb``."""
    if va is None and vb is None:
        return "—", False
    if va is None or vb is None:
        return "only one run", False
    va, vb = float(va), float(vb)
    if va == vb:
        return "unchanged", False
    delta = vb - va
    rel = abs(delta) / max(abs(va), abs(vb), 1e-12)
    arrow = "+" if delta > 0 else ""
    desc = f"{arrow}{_fmt(delta)} ({rel:+.1%})" if delta > 0 else \
        f"{_fmt(delta)} ({-rel:.1%})"
    if higher_better is None or rel <= rtol:
        return desc, False
    regressed = (delta < 0) if higher_better else (delta > 0)
    if regressed:
        return f"{desc} **regression**", True
    return f"{desc} improvement", False
