"""Streaming trace analytics: derived, windowed fleet indicators.

The engines, the net bridge, and the simulation service all *record*
operational signals as raw `TraceEvent`s — arrival instants, window/round
spans, per-upload ``net.upload`` accounting, the ``detect.verdict`` audit
log.  `FleetAnalytics` turns that stream into *answers*: it is a `Sink`
(attach it to a live `Tracer` and every event folds into O(nodes)
running state the moment it is emitted) and equally a post-hoc reducer
(`FleetAnalytics.from_events` replays a recorded stream), maintaining:

  * **per-node straggler scores** — each node's mean inter-arrival gap
    relative to the fleet median (score 1 = typical, k = k-times slower),
    from ``arrival`` instants;
  * **window occupancy / skew** — processed-arrival counts per window
    span against the fleet size, with a trailing deque for "recent"
    views (``round`` spans feed the same series on sync schedules);
  * **byte accounting** — cumulative and per-round/window encoded bytes
    from ``net.upload`` instants (the engines tag each commit batch with
    its round/window id);
  * **detection quality** — accept/reject totals per node, a trailing
    verdict window for drift probes, ring-threshold drift, and — when
    the runner has emitted the ``fleet.population`` ground truth — the
    full confusion matrix (Fig. 6's quality numbers) from the audit log
    alone;
  * **run annotations** — ``sim.event`` / ``sim.heartbeat`` /
    ``health.alert`` / ``health.incident`` events collected for
    postmortem timelines.

Everything is stdlib-only and deterministic: feeding the same event
stream in the same order always yields byte-identical `snapshot()`s.
`repro.obs.health` evaluates live SLO probes against this state and
`repro.obs.report` renders it into postmortems.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, Iterable, List, Optional, Tuple

from .events import TraceEvent
from .sinks import Sink

# trailing-window sizes for the "recent" views (fixed, like the metric
# bucket ladders: determinism beats per-run tuning)
RECENT_WINDOWS = 8
RECENT_VERDICTS = 64
RECENT_THRESHOLDS = 32


class NodeStats:
    """One node's running indicators (arrival cadence, bytes, verdicts)."""
    __slots__ = ("node", "arrivals", "first_t", "last_t", "bytes",
                 "uploads", "accepted", "rejected")

    def __init__(self, node: int):
        self.node = node
        self.arrivals = 0
        self.first_t: Optional[float] = None
        self.last_t: Optional[float] = None
        self.bytes = 0.0
        self.uploads = 0
        self.accepted = 0
        self.rejected = 0

    @property
    def mean_gap(self) -> Optional[float]:
        """Mean inter-arrival gap (needs >= 2 arrivals)."""
        if self.arrivals < 2 or self.last_t is None:
            return None
        span = self.last_t - self.first_t
        return span / (self.arrivals - 1) if span > 0 else None

    def snapshot(self) -> Dict[str, Any]:
        return {"node": self.node, "arrivals": self.arrivals,
                "mean_gap": self.mean_gap, "bytes": self.bytes,
                "uploads": self.uploads, "accepted": self.accepted,
                "rejected": self.rejected}


class FleetAnalytics(Sink):
    """Fold a `TraceEvent` stream into derived fleet indicators.

    Args:
      n_nodes: the fleet size (occupancy denominators).  Discovered from
        the first ``fleet.population`` instant when omitted.
    """

    def __init__(self, n_nodes: Optional[int] = None):
        self.n_nodes = n_nodes
        self.nodes: Dict[int, NodeStats] = {}
        self.malicious: Tuple[int, ...] = ()
        self._have_population = False
        # window/round span series: (id, t0, dur, n_processed, n_rejected)
        self.window_sizes: List[int] = []
        self.recent_windows: Deque[int] = deque(maxlen=RECENT_WINDOWS)
        self.n_windows = 0
        self.n_rounds = 0
        # bytes: cumulative + keyed by the round/window id the engines tag
        self.total_upload_bytes = 0.0
        self.total_uploads = 0
        self.total_retransmits = 0
        self.bytes_by_record: Dict[str, float] = {}
        # detection: totals, trailing verdict window, threshold drift ring
        self.n_verdicts = 0
        self.n_rejected = 0
        self.recent_verdicts: Deque[bool] = deque(maxlen=RECENT_VERDICTS)
        self.recent_thresholds: Deque[float] = deque(
            maxlen=RECENT_THRESHOLDS)
        self.confusion = {"tp": 0, "fp": 0, "tn": 0, "fn": 0}
        # annotations for the postmortem timeline
        self.sim_events: List[Dict[str, Any]] = []
        self.heartbeats: List[Dict[str, Any]] = []
        self.alerts: List[Dict[str, Any]] = []
        self.incidents: List[Dict[str, Any]] = []
        # the stream's virtual-time extent
        self.t_min: Optional[float] = None
        self.t_max: Optional[float] = None

    # -- construction --------------------------------------------------------
    @classmethod
    def from_events(cls, events: Iterable[TraceEvent],
                    n_nodes: Optional[int] = None) -> "FleetAnalytics":
        """Post-hoc reduction of a recorded stream (e.g. `read_events`)."""
        an = cls(n_nodes=n_nodes)
        for ev in events:
            an.emit(ev)
        return an

    # -- the Sink interface --------------------------------------------------
    def emit(self, event: TraceEvent) -> None:
        t = event.virt_t
        if t is not None:
            self.t_min = t if self.t_min is None else min(self.t_min, t)
            end = t + (event.virt_dur or 0.0)
            self.t_max = end if self.t_max is None else max(self.t_max, end)
        name = event.name
        if name == "arrival":
            self._on_arrival(event)
        elif name == "detect.verdict":
            self._on_verdict(event)
        elif name == "net.upload":
            self._on_upload(event)
        elif name in ("window", "round") and event.kind == "span":
            self._on_span(event)
        elif name == "fleet.population":
            self._on_population(event)
        elif name == "sim.event":
            self.sim_events.append(dict(event.tags, t=t))
        elif name == "sim.heartbeat":
            self.heartbeats.append(dict(event.tags))
        elif name == "health.alert":
            self.alerts.append(dict(event.tags, t=t))
        elif name == "health.incident":
            self.incidents.append(dict(event.tags, t=t,
                                       duration=event.virt_dur))

    # -- per-event folds -----------------------------------------------------
    def _node(self, node: int) -> NodeStats:
        st = self.nodes.get(node)
        if st is None:
            st = self.nodes[node] = NodeStats(node)
        return st

    def _on_population(self, ev: TraceEvent) -> None:
        n = ev.tags.get("n_nodes")
        if n is not None and self.n_nodes is None:
            self.n_nodes = int(n)
        self.malicious = tuple(int(m) for m in ev.tags.get("malicious", ()))
        self._have_population = True

    def _on_arrival(self, ev: TraceEvent) -> None:
        node = ev.tags.get("node")
        if node is None or ev.virt_t is None:
            return
        st = self._node(int(node))
        st.arrivals += 1
        if st.first_t is None:
            st.first_t = ev.virt_t
        st.last_t = ev.virt_t

    def _on_verdict(self, ev: TraceEvent) -> None:
        # only armed verdicts count toward detection quality: the engines
        # audit every cloud evaluation, tagging detect=False while the
        # detector is off/warming — those are observations, not verdicts
        if not ev.tags.get("detect", True):
            return
        node = ev.tags.get("node")
        rejected = bool(ev.tags.get("rejected", False))
        self.n_verdicts += 1
        self.n_rejected += rejected
        self.recent_verdicts.append(rejected)
        thr = ev.tags.get("threshold")
        if thr is not None:
            self.recent_thresholds.append(float(thr))
        if node is not None:
            st = self._node(int(node))
            if rejected:
                st.rejected += 1
            else:
                st.accepted += 1
        if self._have_population and node is not None:
            bad = int(node) in set(self.malicious)
            key = ("tp" if rejected else "fn") if bad else \
                ("fp" if rejected else "tn")
            self.confusion[key] += 1

    def _on_upload(self, ev: TraceEvent) -> None:
        node = ev.tags.get("node")
        nbytes = float(ev.tags.get("encoded_bytes", 0.0))
        self.total_upload_bytes += nbytes
        self.total_uploads += 1
        self.total_retransmits += int(ev.tags.get("retransmits", 0))
        if node is not None:
            st = self._node(int(node))
            st.bytes += nbytes
            st.uploads += 1
        for key in ("round", "window"):
            rid = ev.tags.get(key)
            if rid is not None:
                k = f"{key}:{int(rid)}"
                self.bytes_by_record[k] = \
                    self.bytes_by_record.get(k, 0.0) + nbytes
                break

    def _on_span(self, ev: TraceEvent) -> None:
        tags = ev.tags
        if ev.name == "round":
            self.n_rounds += 1
            size = tags.get("n_participating")
        else:
            self.n_windows += 1
            size = tags.get("n_processed")
        if size is not None:
            self.window_sizes.append(int(size))
            self.recent_windows.append(int(size))

    # -- derived indicators --------------------------------------------------
    def straggler_scores(self, min_arrivals: int = 2) -> Dict[int, float]:
        """node -> inter-arrival gap / fleet median gap.  A node at score
        k arrives k-times slower than the typical node.

        Nodes with a real cadence (>= 2 arrivals) use their mean
        inter-arrival gap; nodes the stream has barely seen use the run
        extent over their arrival count — a *lower bound* on their true
        gap, which is exactly the straggler signature in a fixed-arrival-
        budget run (the slow tail shows up as absence, not as long
        measured gaps).  Nothing is scored until the fleet-median node
        has >= ``min_arrivals`` arrivals (a cold fleet has no baseline
        cadence)."""
        if self.t_min is None or self.t_max is None:
            return {}
        extent = self.t_max - self.t_min
        if extent <= 0:
            return {}
        n_ids = self.n_nodes or (max(self.nodes) + 1 if self.nodes else 0)
        gaps: Dict[int, float] = {}
        counts: List[int] = []
        for n in range(n_ids):
            st = self.nodes.get(n)
            arr = st.arrivals if st is not None else 0
            counts.append(arr)
            mg = st.mean_gap if st is not None else None
            gaps[n] = (mg if arr >= 2 and mg is not None
                       else extent / max(1, arr))
        if not counts or _median(sorted(counts)) < max(2, min_arrivals):
            return {}
        med = _median(sorted(gaps.values()))
        if med <= 0:
            return {}
        return {n: g / med for n, g in sorted(gaps.items())}

    def top_stragglers(self, k: int = 5,
                       min_arrivals: int = 2) -> List[Dict[str, Any]]:
        scores = self.straggler_scores(min_arrivals)
        top = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
        return [dict((self.nodes[n] if n in self.nodes
                      else NodeStats(n)).snapshot(), score=s)
                for n, s in top]

    def recent_occupancy(self) -> Optional[float]:
        """Mean processed-arrival count over the trailing windows, as a
        fraction of the fleet (None until a span has landed or the fleet
        size is unknown)."""
        if not self.recent_windows or not self.n_nodes:
            return None
        return (sum(self.recent_windows)
                / len(self.recent_windows) / self.n_nodes)

    def window_skew(self) -> Optional[float]:
        """max/median window size over the trailing windows — 1 means
        even composition, large values mean a few windows swallow the
        fleet (the straggler/flash-crowd signature)."""
        if not self.recent_windows:
            return None
        med = _median(sorted(self.recent_windows))
        return max(self.recent_windows) / med if med > 0 else None

    def recent_reject_rate(self, window: int) -> Optional[float]:
        """Rejected fraction of the trailing ``window`` verdicts (None
        until that many verdicts have been audited)."""
        if window < 1 or len(self.recent_verdicts) < window:
            return None
        tail = list(self.recent_verdicts)[-window:]
        return sum(tail) / window

    def reject_rate(self) -> Optional[float]:
        return (self.n_rejected / self.n_verdicts if self.n_verdicts
                else None)

    def threshold_drift(self) -> Optional[float]:
        """Detection ring-threshold drift: last threshold minus the
        median of the trailing ring (the percentile gate shifting under
        an attack or accuracy regime change)."""
        if len(self.recent_thresholds) < 2:
            return None
        ring = sorted(self.recent_thresholds)
        return self.recent_thresholds[-1] - _median(ring)

    def detection_quality(self) -> Dict[str, Any]:
        """Confusion counts + precision/recall/accuracy against the
        ``fleet.population`` ground truth (zeros when never emitted)."""
        c = dict(self.confusion)
        tp, fp, tn, fn = c["tp"], c["fp"], c["tn"], c["fn"]
        total = tp + fp + tn + fn
        c["precision"] = tp / (tp + fp) if tp + fp else None
        c["recall"] = tp / (tp + fn) if tp + fn else None
        c["accuracy"] = (tp + tn) / total if total else None
        c["ground_truth"] = self._have_population
        return c

    def final_accuracy(self) -> Optional[float]:
        if self.heartbeats:
            return self.heartbeats[-1].get("accuracy")
        return None

    def snapshot(self) -> Dict[str, Any]:
        """Every indicator as one deterministic JSON-ready dict (the
        report/diff surface)."""
        sizes = sorted(self.window_sizes)
        return {
            "n_nodes": self.n_nodes,
            "nodes_seen": len(self.nodes),
            "virtual_extent": [self.t_min, self.t_max],
            "n_windows": self.n_windows,
            "n_rounds": self.n_rounds,
            "occupancy_recent": self.recent_occupancy(),
            "window_skew": self.window_skew(),
            "window_size_median": _median(sizes) if sizes else None,
            "total_upload_bytes": self.total_upload_bytes,
            "total_uploads": self.total_uploads,
            "total_retransmits": self.total_retransmits,
            "bytes_by_record": dict(sorted(self.bytes_by_record.items())),
            "n_verdicts": self.n_verdicts,
            "n_rejected": self.n_rejected,
            "reject_rate": self.reject_rate(),
            "threshold_drift": self.threshold_drift(),
            "detection": self.detection_quality(),
            "straggler_scores": {str(n): s for n, s in
                                 self.straggler_scores().items()},
            "final_accuracy": self.final_accuracy(),
            "n_sim_events": len(self.sim_events),
            "n_alerts": len(self.alerts),
            "n_incidents": len(self.incidents),
        }


def _median(sorted_vals: List[float]) -> float:
    n = len(sorted_vals)
    mid = n // 2
    if n % 2:
        return float(sorted_vals[mid])
    return (sorted_vals[mid - 1] + sorted_vals[mid]) / 2.0
