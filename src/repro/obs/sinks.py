"""Event sinks: in-memory, streaming JSONL, and the Chrome-trace exporter.

  * `MemorySink`  — collect `TraceEvent`s in a list (tests, and the
    staging buffer the Chrome-trace export reads from);
  * `JsonlSink`   — stream events to disk as one JSON object per line,
    flushed per record, via the shared `JsonlWriter`;
  * `JsonlWriter` — the crash-safe append-per-line primitive (schema-
    stamped header line, O(1) appends, `read_jsonl` rejects or drops a
    torn final line) — also used by `api.run` to stream `RoundRecord`s
    incrementally instead of the at-end JSON dump;
  * `chrome_trace`/`write_chrome_trace` — render an event list in the
    Chrome ``trace_event`` format Perfetto loads: nodes become tracks,
    windows/stages become duration slices, arrivals/verdicts become
    instants.  Timestamps prefer the *virtual* clock (the simulation's
    arrival times) and fall back to wall time, so an async run renders
    as the timeline the paper reasons about.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional

from .events import TraceEvent

# version of the JSONL event/record stream layout (independent of the
# api's spec/report schema_version — obs is a lower layer)
OBS_SCHEMA_VERSION = 1


class Sink:
    """Interface: `emit(event)` per record, `close()` once at run end."""

    def emit(self, event: TraceEvent) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Keep every event in memory — tests and the Chrome-trace staging
    buffer."""

    def __init__(self):
        self.events: List[TraceEvent] = []

    def emit(self, event: TraceEvent) -> None:
        self.events.append(event)


# ---------------------------------------------------------------------------
# crash-safe JSONL streaming
# ---------------------------------------------------------------------------

class JsonlWriter:
    """Append-per-record JSONL file: one JSON object per line, flushed
    after every write, opened with a schema-stamped header line.

    Crash safety is the point: a process killed mid-run leaves every
    *completed* line intact and at most one torn final line, which
    `read_jsonl` detects — unlike a single JSON document, where a
    mid-write crash corrupts the whole file.
    """

    def __init__(self, path: str, header: Optional[Dict[str, Any]] = None):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "w")
        h = {"kind": "header", "obs_schema": OBS_SCHEMA_VERSION}
        if header:
            h.update(header)
        self.write(h)

    def write(self, record: Dict[str, Any]) -> None:
        self._f.write(json.dumps(record, separators=(",", ":")) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_jsonl(path: str, strict: bool = True) -> List[Dict[str, Any]]:
    """Read a `JsonlWriter` stream back (header line included).

    A torn final line — the signature of a crash mid-append — raises a
    clear ValueError under ``strict=True`` (the default: silent data loss
    is worse than a loud stop) and is dropped under ``strict=False`` (how
    a resuming service would reopen its own stream).  A torn line
    *before* the end is corruption, not a crash artifact, and always
    raises.
    """
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        lines = f.read().split("\n")
    if lines and lines[-1] == "":
        lines.pop()                     # trailing newline = clean last line
    for i, line in enumerate(lines):
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError as e:
            if i == len(lines) - 1:
                if strict:
                    raise ValueError(
                        f"{path}: truncated final JSONL line (crash "
                        f"mid-append?) — re-read with strict=False to "
                        f"drop it: {line[:80]!r}") from e
                break
            raise ValueError(f"{path}: corrupt JSONL at line {i + 1}: "
                             f"{line[:80]!r}") from e
    return out


class JsonlSink(Sink):
    """Stream `TraceEvent`s through a `JsonlWriter`."""

    def __init__(self, path: str, header: Optional[Dict[str, Any]] = None):
        self.writer = JsonlWriter(path, header=header)

    def emit(self, event: TraceEvent) -> None:
        self.writer.write(event.to_dict())

    def close(self) -> None:
        self.writer.close()


def read_events(path: str, strict: bool = True) -> List[TraceEvent]:
    """Load the `TraceEvent`s out of a `JsonlSink` stream (header and any
    non-event records skipped)."""
    return [TraceEvent.from_dict(d) for d in read_jsonl(path, strict=strict)
            if d.get("kind") in ("span", "instant", "counter")]


# ---------------------------------------------------------------------------
# Chrome trace_event / Perfetto export
# ---------------------------------------------------------------------------

_CLOUD_TRACK = "cloud"


def _track(ev: TraceEvent) -> str:
    node = ev.tags.get("node")
    return f"node {node}" if node is not None else _CLOUD_TRACK


def _ts_us(ev: TraceEvent, wall0: float) -> float:
    """Microsecond timestamp: virtual clock when stamped, else wall time
    rebased to the trace start (both end up on one comparable axis only
    when the whole stream uses one clock kind — engines stamp virt_t on
    everything simulation-side)."""
    if ev.virt_t is not None:
        return ev.virt_t * 1e6
    return (ev.wall_t - wall0) * 1e6


def chrome_trace(events: Iterable[TraceEvent]) -> Dict[str, Any]:
    """Render events as a Chrome ``trace_event`` JSON object (Perfetto and
    chrome://tracing both load it): spans -> complete ("X") slices,
    instants -> "i", counters -> "C"; one tid per node plus a cloud
    track."""
    events = list(events)
    wall0 = min((e.wall_t for e in events), default=0.0)
    tids: Dict[str, int] = {}
    out: List[Dict[str, Any]] = []

    def tid_for(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": tids[track], "args": {"name": track}})
        return tids[track]

    tid_for(_CLOUD_TRACK)               # stable tid 1 for the cloud track
    for ev in sorted(events, key=lambda e: e.seq):
        track = _track(ev)
        tid = tid_for(track)
        ts = _ts_us(ev, wall0)
        args = {k: v for k, v in ev.tags.items()}
        if ev.kind == "span":
            dur = ((ev.virt_dur if ev.virt_dur is not None else ev.dur)
                   or 0.0) * 1e6
            out.append({"ph": "X", "name": ev.name, "pid": 1, "tid": tid,
                        "ts": ts, "dur": dur, "args": args})
        elif ev.kind == "instant":
            out.append({"ph": "i", "name": ev.name, "pid": 1, "tid": tid,
                        "ts": ts, "s": "t", "args": args})
        else:
            # counter -> a Perfetto *counter track* ("C" samples render as
            # a stepped series, not instant markers).  Counter tracks are
            # identified by (pid, name), so per-node counters get the
            # track folded into the name — each node plots as its own
            # series instead of interleaving into one garbled track.
            cname = (ev.name if track == _CLOUD_TRACK
                     else f"{ev.name} ({track})")
            out.append({"ph": "C", "name": cname, "pid": 1, "tid": tid,
                        "ts": ts, "args": {ev.name: ev.value}})
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs",
                          "obs_schema": OBS_SCHEMA_VERSION}}


def write_chrome_trace(path: str, events: Iterable[TraceEvent]) -> None:
    """Write the Chrome-trace JSON via temp-file rename (the export runs
    at run end — a crash must not leave a half-written trace that looks
    loadable)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(chrome_trace(events), f)
    os.replace(tmp, path)
