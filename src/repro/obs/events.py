"""Structured event tracing: `TraceEvent` + the process-global `Tracer`.

The paper's claims are time-series claims — comm-overhead curves over
virtual time, async window composition, detection firing per arrival — so
the repro needs a structured event stream, not prints.  A `TraceEvent`
carries *both* clocks: host wall time (``wall_t``/``dur``, from
`time.perf_counter`) and the simulation's virtual time (``virt_t``/
``virt_dur``, the engines' arrival clocks), plus free-form tags
(``node``/``round``/``window``/...) that the sinks turn into tracks.

Three event kinds:

  * ``span``    — a named interval (an arrival window, a pipeline stage);
    emitted once at exit with its start time and duration.  Spans nest —
    `Tracer.span` is a context manager.
  * ``instant`` — a point event (one arrival, one detection verdict).
  * ``counter`` — a named sample (bytes uploaded, window size).

The tracer is **explicitly injectable and no-op when disabled**: every
hot-path call sites `if tracer.enabled:` first (one attribute read), and
the disabled `span()` returns a shared null context manager, so jitted
paths and analytic runs pay nothing.  A process-global default
(`get_tracer`/`set_tracer`/`use_tracer`) lets layers that never see the
`api.ObsSpec` (kernels benchmarks, the net bridge) share one stream.

Zero dependencies beyond the stdlib — `repro.obs` sits below every other
subsystem and must import nothing from them.
"""
from __future__ import annotations

import contextlib
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

EVENT_KINDS = ("span", "instant", "counter")


@dataclass
class TraceEvent:
    """One structured trace record (see module docstring for the kinds)."""
    kind: str                           # span | instant | counter
    name: str
    wall_t: float                       # host perf_counter seconds
    virt_t: Optional[float] = None      # simulation virtual time (seconds)
    dur: Optional[float] = None         # span: host wall duration
    virt_dur: Optional[float] = None    # span: virtual-time duration
    value: Optional[float] = None       # counter: the sampled value
    tags: Dict[str, Any] = field(default_factory=dict)
    seq: int = 0                        # per-tracer emission order

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "name": self.name,
                             "wall_t": self.wall_t, "seq": self.seq}
        for k in ("virt_t", "dur", "virt_dur", "value"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.tags:
            d["tags"] = self.tags
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        if d.get("kind") not in EVENT_KINDS:
            raise ValueError(f"TraceEvent.kind {d.get('kind')!r} not in "
                             f"{EVENT_KINDS}")
        return cls(kind=d["kind"], name=d["name"], wall_t=d["wall_t"],
                   virt_t=d.get("virt_t"), dur=d.get("dur"),
                   virt_dur=d.get("virt_dur"), value=d.get("value"),
                   tags=dict(d.get("tags", {})), seq=int(d.get("seq", 0)))


class _NullSpan:
    """The shared do-nothing context manager a disabled tracer hands out."""
    __slots__ = ()

    def set(self, **tags) -> None:
        pass

    def set_virtual(self, virt_t=None, virt_end=None) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """An open span: emitted as one `TraceEvent` when the context exits."""
    __slots__ = ("_tracer", "name", "virt_t", "virt_end", "tags", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 virt_t: Optional[float], virt_end: Optional[float],
                 tags: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.virt_t = virt_t
        self.virt_end = virt_end
        self.tags = tags
        self._t0 = 0.0

    def set(self, **tags) -> None:
        """Attach tags discovered mid-span (window composition counts,
        byte totals) before the span closes."""
        self.tags.update(tags)

    def set_virtual(self, virt_t: Optional[float] = None,
                    virt_end: Optional[float] = None) -> None:
        if virt_t is not None:
            self.virt_t = virt_t
        if virt_end is not None:
            self.virt_end = virt_end

    def __enter__(self):
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer.clock()
        virt_dur = (self.virt_end - self.virt_t
                    if self.virt_end is not None and self.virt_t is not None
                    else None)
        self._tracer.emit(TraceEvent(
            kind="span", name=self.name, wall_t=self._t0, dur=t1 - self._t0,
            virt_t=self.virt_t, virt_dur=virt_dur, tags=self.tags))
        return False


class Tracer:
    """The event stream head: fan events out to sinks, own a metrics
    registry, stamp emission order.

    ``enabled=False`` (the default of the process-global tracer) makes
    every method a near-free no-op — instrumented code guards with
    ``if tracer.enabled:`` for zero-cost disabled paths, but calling
    through is also safe.
    """

    def __init__(self, sinks: Iterable = (), enabled: bool = True,
                 clock=time.perf_counter, metrics=None,
                 stage_timings: bool = False):
        self.sinks: List = list(sinks)
        self.enabled = bool(enabled)
        # measurement mode: fence + time host pipeline stages (serializes
        # JAX async dispatch, so it is a separate opt-in from `enabled`)
        self.stage_timings = bool(stage_timings)
        self.clock = clock
        self._seq = itertools.count()
        if metrics is None:
            from .metrics import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics

    # -- emission -----------------------------------------------------------
    def emit(self, event: TraceEvent) -> None:
        if not self.enabled:
            return
        event.seq = next(self._seq)
        for sink in self.sinks:
            sink.emit(event)

    def instant(self, name: str, virt_t: Optional[float] = None,
                **tags) -> None:
        if not self.enabled:
            return
        self.emit(TraceEvent(kind="instant", name=name, wall_t=self.clock(),
                             virt_t=virt_t, tags=tags))

    def counter(self, name: str, value: float,
                virt_t: Optional[float] = None, **tags) -> None:
        if not self.enabled:
            return
        self.emit(TraceEvent(kind="counter", name=name, wall_t=self.clock(),
                             virt_t=virt_t, value=float(value), tags=tags))

    def span(self, name: str, virt_t: Optional[float] = None,
             virt_end: Optional[float] = None, **tags):
        """Nestable span context manager; a disabled tracer returns a
        shared null context (no allocation, no clock read)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, virt_t, virt_end, tags)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        for sink in self.sinks:
            sink.close()


# ---------------------------------------------------------------------------
# the process-global tracer (disabled by default: jit paths pay nothing)
# ---------------------------------------------------------------------------

_GLOBAL_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The current process-global tracer (a disabled no-op unless a run
    installed one via `set_tracer`/`use_tracer`)."""
    return _GLOBAL_TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-global default; returns the
    previous one so callers can restore it."""
    global _GLOBAL_TRACER
    prev = _GLOBAL_TRACER
    _GLOBAL_TRACER = tracer
    return prev


@contextlib.contextmanager
def use_tracer(tracer: Tracer):
    """Scoped install: the global tracer is ``tracer`` inside the with
    block and restored after — how `api.run` scopes one run's stream."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
