"""Fleet health: declarative SLO probes and incident detection.

`HealthSpec` declares *what healthy looks like* — a straggler factor the
slowest nodes must stay under, a per-record byte budget the compressed
uplink must fit, a ceiling on the detector's recent reject rate, a floor
on window occupancy.  `HealthMonitor` evaluates those probes between
records against the running `FleetAnalytics` state and writes what it
finds back into the *same* trace stream everything else records to:

  * ``health.alert``    — an instant the moment a probe trips (probe,
    subject node, observed value, threshold);
  * ``health.incident`` — a span emitted when the condition *clears*
    (or at run end via `finalize`), carrying the full virtual-time
    extent, so Perfetto renders outages as slices and `obs_report` can
    build an incident timeline from the trace alone.

Probes are level-triggered with per-subject dedup: a straggler that
stays slow for forty records is one incident with a forty-record extent,
not forty alerts.  The monitor only *reads* analytics and *emits*
events — it never touches engine state, so runs with health disabled
(the default) are bit-identical to runs without the feature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from .analysis import FleetAnalytics
from .events import TraceEvent, Tracer


@dataclasses.dataclass(frozen=True)
class HealthSpec:
    """Declarative SLO rules / anomaly probes (the `ObsSpec.health` axis).

    Every probe defaults to *off* (threshold 0) — an empty `HealthSpec`
    is rejected by `compile_plan`, enable at least one probe.

      straggler_factor: flag node i when its inter-arrival gap (measured
        cadence, or the run-extent lower bound for barely-seen nodes)
        exceeds ``factor`` times the fleet median (> 1 when set; needs an
        async/buffered schedule — sync rounds have no arrival cadence).
      straggler_min_arrivals: fleet-median arrivals before cadence is
        scored at all (>= 2 — a cold fleet has no baseline).
      bytes_per_record_budget: flag a round/window whose committed upload
        bytes exceed this budget (requires ``network.enabled``).
      reject_rate_threshold: flag when the rejected fraction of the
        trailing ``reject_rate_window`` verdicts exceeds this (in (0, 1];
        requires ``defense.detect`` — the drift signature of an attack
        onset or a mis-tuned trust ring).
      reject_rate_window: trailing verdict count for the rate (>= 1).
      occupancy_floor: flag when mean processed arrivals per recent
        window falls below this fraction of the fleet (in (0, 1)).
      warmup_records: records before any probe may fire (cold-start
        arrival gaps and an empty trust ring look pathological).
    """
    straggler_factor: float = 0.0
    straggler_min_arrivals: int = 3
    bytes_per_record_budget: float = 0.0
    reject_rate_threshold: float = 0.0
    reject_rate_window: int = 16
    occupancy_floor: float = 0.0
    warmup_records: int = 2

    def enabled_probes(self) -> Tuple[str, ...]:
        out = []
        if self.straggler_factor:
            out.append("straggler")
        if self.bytes_per_record_budget:
            out.append("byte_budget")
        if self.reject_rate_threshold:
            out.append("reject_rate")
        if self.occupancy_floor:
            out.append("occupancy")
        return tuple(out)


class _Incident:
    """An open condition: (probe, subject) -> first-trip bookkeeping."""
    __slots__ = ("probe", "subject", "opened_t", "opened_record",
                 "worst", "threshold", "polls")

    def __init__(self, probe: str, subject: Optional[int], t: float,
                 record: int, value: float, threshold: float):
        self.probe = probe
        self.subject = subject
        self.opened_t = t
        self.opened_record = record
        self.worst = value
        self.threshold = threshold
        self.polls = 1

    def update(self, value: float, worse_is_higher: bool) -> None:
        self.polls += 1
        self.worst = (max(self.worst, value) if worse_is_higher
                      else min(self.worst, value))


class HealthMonitor:
    """Evaluate a `HealthSpec` against live `FleetAnalytics` state.

    `evaluate(virt_t, records_done)` is called between records (the
    service's `_pre_dispatch`, or a post-run sweep); `finalize(virt_t)`
    closes whatever is still open at run end.
    """

    def __init__(self, spec: HealthSpec, analytics: FleetAnalytics,
                 tracer: Tracer, n_nodes: int):
        self.spec = spec
        self.analytics = analytics
        self.tracer = tracer
        self.n_nodes = n_nodes
        self.open: Dict[Tuple[str, Optional[int]], _Incident] = {}
        self.closed: List[Dict[str, Any]] = []
        self._last_record = -1
        self._bytes_at_record = 0.0
        self._finalized = False

    # -- probe evaluation ----------------------------------------------------
    def evaluate(self, virt_t: float, records_done: int) -> None:
        if self._finalized or records_done < self.spec.warmup_records:
            # still track the byte watermark so the budget probe measures
            # post-warmup deltas, not the whole cold start at once
            self._note_record(records_done)
            return
        sp, an = self.spec, self.analytics
        trips: Dict[Tuple[str, Optional[int]], Tuple[float, float]] = {}

        if sp.straggler_factor:
            scores = an.straggler_scores(sp.straggler_min_arrivals)
            for node, score in scores.items():
                if score > sp.straggler_factor:
                    trips[("straggler", node)] = (score, sp.straggler_factor)

        if sp.bytes_per_record_budget and records_done > self._last_record:
            delta = an.total_upload_bytes - self._bytes_at_record
            n_rec = records_done - self._last_record
            per_record = delta / n_rec
            if per_record > sp.bytes_per_record_budget:
                trips[("byte_budget", None)] = (
                    per_record, sp.bytes_per_record_budget)

        if sp.reject_rate_threshold:
            rate = an.recent_reject_rate(sp.reject_rate_window)
            if rate is not None and rate > sp.reject_rate_threshold:
                trips[("reject_rate", None)] = (rate,
                                                sp.reject_rate_threshold)

        if sp.occupancy_floor:
            occ = an.recent_occupancy()
            if occ is not None and occ < sp.occupancy_floor:
                trips[("occupancy", None)] = (occ, sp.occupancy_floor)

        self._note_record(records_done)

        # open / refresh tripped conditions, close cleared ones
        for key, (value, threshold) in sorted(
                trips.items(), key=lambda kv: (kv[0][0], kv[0][1] or -1)):
            inc = self.open.get(key)
            if inc is None:
                probe, subject = key
                self.open[key] = _Incident(probe, subject, virt_t,
                                           records_done, value, threshold)
                self._alert(probe, subject, value, threshold, virt_t,
                            records_done)
            else:
                inc.update(value, worse_is_higher=key[0] != "occupancy")
        for key in sorted(self.open.keys() - trips.keys(),
                          key=lambda k: (k[0], k[1] or -1)):
            self._close(self.open.pop(key), virt_t, records_done,
                        resolved=True)

    def finalize(self, virt_t: float, records_done: int) -> None:
        """Close every still-open incident (run end is not resolution —
        the span is tagged ``resolved=False``)."""
        if self._finalized:
            return
        self._finalized = True
        for key in sorted(self.open.keys(), key=lambda k: (k[0],
                                                           k[1] or -1)):
            self._close(self.open.pop(key), virt_t, records_done,
                        resolved=False)

    # -- event emission ------------------------------------------------------
    def _alert(self, probe: str, subject: Optional[int], value: float,
               threshold: float, virt_t: float, record: int) -> None:
        tags: Dict[str, Any] = {"probe": probe, "value": value,
                                "threshold": threshold, "record": record}
        if subject is not None:
            tags["node"] = subject
        self.tracer.instant("health.alert", virt_t=virt_t, **tags)
        self.tracer.metrics.counter("health.alerts").inc()
        self.tracer.metrics.counter(f"health.alerts.{probe}").inc()

    def _close(self, inc: _Incident, virt_t: float, record: int,
               resolved: bool) -> None:
        tags: Dict[str, Any] = {
            "probe": inc.probe, "worst": inc.worst,
            "threshold": inc.threshold, "resolved": resolved,
            "opened_record": inc.opened_record, "closed_record": record,
            "polls": inc.polls}
        if inc.subject is not None:
            tags["node"] = inc.subject
        self.tracer.emit(TraceEvent(
            kind="span", name="health.incident",
            wall_t=self.tracer.clock(), virt_t=inc.opened_t,
            virt_dur=max(0.0, virt_t - inc.opened_t), tags=tags))
        self.tracer.metrics.counter("health.incidents").inc()
        self.tracer.metrics.counter(f"health.incidents.{inc.probe}").inc()
        self.closed.append(dict(tags, opened_t=inc.opened_t,
                                closed_t=virt_t))

    def _note_record(self, records_done: int) -> None:
        if records_done > self._last_record:
            self._last_record = records_done
            self._bytes_at_record = self.analytics.total_upload_bytes
