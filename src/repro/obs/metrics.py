"""Typed metrics registry: counters, gauges, histograms with fixed buckets.

Aggregated (as opposed to per-event) observability: upload counts, encoded
vs analytic byte totals, window sizes and staleness, detection verdicts,
retransmits/loss from the link model.  Three metric types:

  * `Counter`   — monotone accumulator (`inc`);
  * `Gauge`     — last-written value (`set`);
  * `Histogram` — counts over **fixed, caller-declared bucket edges** so
    two runs of the same spec produce byte-identical snapshots (no
    dynamic rebinning — determinism is part of the contract, the same
    discipline as the fixed detection ring).

`MetricsRegistry.snapshot()` reduces everything to one sorted, JSON-ready
dict; `Tracer` owns a registry (`tracer.metrics`) so instrumented layers
share a single handle, but the registry is independently constructible
for tests.  Stdlib-only, like the rest of `repro.obs`.
"""
from __future__ import annotations

import bisect
import re
from typing import Dict, List, Optional, Sequence, Tuple


class Counter:
    """Monotone accumulator.  ``inc`` by any non-negative amount."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"Counter {self.name!r} is monotone; "
                             f"inc({amount}) would decrease it")
        self.value += float(amount)

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value (window size, ring occupancy, current version)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram.

    ``edges`` are the finite upper bounds; observations land in the first
    bucket whose edge is >= the value, with one implicit +inf overflow
    bucket.  Edges are frozen at construction — re-requesting the same
    histogram with different edges is an error (silently merging two
    binnings would make snapshots meaningless).
    """
    __slots__ = ("name", "edges", "counts", "total", "sum", "min", "max")

    def __init__(self, name: str, edges: Sequence[float]):
        e = tuple(float(x) for x in edges)
        if not e or any(b <= a for a, b in zip(e, e[1:])):
            raise ValueError(f"Histogram {name!r} needs strictly increasing "
                             f"non-empty bucket edges, got {edges}")
        self.name = name
        self.edges = e
        self.counts = [0] * (len(e) + 1)    # +1: the +inf overflow bucket
        self.total = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.total += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def quantile(self, q: float) -> Optional[float]:
        """Estimate the q-quantile by linear interpolation inside the
        bucket the target rank lands in.

        Bucket i spans ``(edges[i-1], edges[i]]``; the first bucket's
        lower bound and the overflow bucket's upper bound are the
        *observed* min/max (tracked per histogram), so the estimate is
        always inside the observed range — tighter than the Prometheus
        convention of clamping to the outermost edge.  Returns None on an
        empty histogram.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {q}")
        if self.total == 0:
            return None
        target = q * self.total
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else self.min
                hi = (self.edges[i] if i < len(self.edges) else self.max)
                frac = (target - cum) / c if c else 0.0
                v = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return float(min(max(v, self.min), self.max))
            cum += c
        return float(self.max)

    def snapshot(self) -> Dict:
        return {"type": "histogram", "edges": list(self.edges),
                "counts": list(self.counts), "count": self.total,
                "sum": self.sum, "min": self.min, "max": self.max}


class MetricsRegistry:
    """Name -> metric, created on first touch, type-checked on re-touch."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
            return m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {type(m).__name__}, "
                            f"requested as {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, edges: Sequence[float]) -> Histogram:
        h = self._get(name, Histogram, edges)
        if h.edges != tuple(float(x) for x in edges):
            raise ValueError(f"histogram {name!r} already registered with "
                             f"edges {h.edges}, re-requested with {edges}")
        return h

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict]:
        """Deterministic (sorted-key) dump of every metric — what the obs
        session appends to the event JSONL at run end."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def to_prom_text(self) -> str:
        """The registry as Prometheus text exposition (version 0.0.4):
        ``# TYPE`` line per metric, cumulative ``_bucket{le=...}`` series
        plus ``_sum``/``_count`` for histograms.  Metric names are
        sanitized to the Prometheus charset (dots become underscores), so
        a snapshot served or dumped this way is scrapeable as-is."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _prom_name(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_prom_float(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_float(m.value)}")
            else:                       # Histogram: cumulative buckets
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for edge, c in zip(m.edges, m.counts):
                    cum += c
                    lines.append(f'{pname}_bucket{{le="{_prom_float(edge)}"}}'
                                 f" {cum}")
                cum += m.counts[-1]
                lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{pname}_sum {_prom_float(m.sum)}")
                lines.append(f"{pname}_count {m.total}")
        return "\n".join(lines) + ("\n" if lines else "")


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    out = _PROM_BAD.sub("_", name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_float(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# Shared bucket ladders: powers-of-two style edges the engines use so
# window-size / staleness / transfer-time histograms are comparable across
# runs and benchmarks without per-run tuning.
WINDOW_SIZE_EDGES: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096, 16384)
STALENESS_EDGES: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64, 128)
SECONDS_EDGES: Tuple[float, ...] = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0,
    100.0, 300.0, 1000.0)
