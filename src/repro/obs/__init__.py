"""`repro.obs`: structured event tracing, metrics, and profiling hooks.

The observability layer under the whole fleet/net/kernel stack:

  * `events`  — `TraceEvent` (span/instant/counter, wall + virtual time,
    node/round/window tags), the nestable-`span()` `Tracer`, and the
    process-global injectable default (`get_tracer`/`set_tracer`/
    `use_tracer`) that is a no-op when disabled;
  * `metrics` — typed registry (counters, gauges, fixed-bucket
    histograms) with a deterministic `snapshot()`;
  * `sinks`   — crash-safe streaming JSONL (`JsonlWriter`/`JsonlSink` +
    `read_jsonl`), `MemorySink` for tests, and the Chrome-trace/Perfetto
    exporter (`chrome_trace`/`write_chrome_trace`);
  * `timers`  — `block_until_ready`-fenced per-stage timing
    (`timed_stage`) and the kernel profiling primitive (`bench_kernel`);
  * `analysis` — `FleetAnalytics`, the streaming trace-analytics sink
    folding arrival/window/upload/verdict events into derived fleet
    indicators (straggler scores, occupancy/skew, byte accounting,
    detection confusion);
  * `health`  — declarative `HealthSpec` SLO probes and the
    `HealthMonitor` that turns analytics state into `health.alert`
    instants and `health.incident` spans in the same trace stream;
  * `report`  — trace-only Markdown postmortems (`postmortem_md`) and
    run-vs-run diffs (`run_diff_md`), fronted by `tools/obs_report.py`.

Enabled per experiment through `api.ObsSpec`; with the spec at its
default (off) no event is constructed and the engines' jitted programs
are unchanged — tracing costs nothing until asked for.  `repro.obs`
imports nothing from the rest of the repo (and jax only lazily, for
fencing), so every layer down to the kernels can depend on it.
"""
from .analysis import FleetAnalytics, NodeStats  # noqa: F401
from .events import (TraceEvent, Tracer, get_tracer,  # noqa: F401
                     set_tracer, use_tracer)
from .health import HealthMonitor, HealthSpec  # noqa: F401
from .metrics import (SECONDS_EDGES, STALENESS_EDGES,  # noqa: F401
                      WINDOW_SIZE_EDGES, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .report import postmortem_md, run_diff_md  # noqa: F401
from .sinks import (OBS_SCHEMA_VERSION, JsonlSink, JsonlWriter,  # noqa: F401
                    MemorySink, Sink, chrome_trace, read_events,
                    read_jsonl, write_chrome_trace)
from .timers import bench_kernel, fence, timed_stage  # noqa: F401
