#!/usr/bin/env bash
# One-command smoke: module-import sweep + tier-1 pytest + a 2-round fleet
# run on synthetic data.
#
#   bash tools/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== 1/11 import sweep (every repro.* and benchmarks.* module) =="
python - <<'EOF'
import importlib
import pkgutil

import repro

failures = []
mods = ["repro"] + [m.name for m in
                    pkgutil.walk_packages(repro.__path__, "repro.")]
import benchmarks
mods += ["benchmarks"] + [m.name for m in
                          pkgutil.walk_packages(benchmarks.__path__,
                                                "benchmarks.")]
for name in mods:
    try:
        importlib.import_module(name)
    except Exception as e:  # noqa: BLE001 - report every broken module
        failures.append((name, repr(e)))
for name, err in failures:
    print(f"IMPORT FAILED: {name}: {err}")
print(f"imported {len(mods) - len(failures)}/{len(mods)} modules")
raise SystemExit(1 if failures else 0)
EOF

echo "== 2/11 tier-1 pytest =="
python -m pytest -q

echo "== 3/11 fleet smokes on synthetic data (2 sync rounds + 2 async windows) =="
python -m benchmarks.fleet_scale --smoke
python -m benchmarks.async_scale --smoke

echo "== 4/11 multi-device sharded fleet smoke (4 forced host devices) =="
python -m benchmarks.fleet_shard --smoke

echo "== 5/11 api smoke (spec -> plan -> run, every schedule x topology) =="
python -m benchmarks.api_smoke
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    python -m benchmarks.api_smoke --mesh 2

echo "== 6/11 network smoke (wire codecs + lossy-link run) =="
python -m benchmarks.net_sweep --smoke

echo "== 7/11 pallas fused-kernel smoke (megakernel + window-fold engines) =="
python -m benchmarks.api_smoke --backend pallas

echo "== 8/11 obs smoke (traced run + pinned benchmark baselines) =="
python -m benchmarks.obs_smoke
python tools/bench_check.py

echo "== 9/11 attack-matrix smoke (adversary zoo x defense x schedule) =="
python -m benchmarks.attack_matrix --smoke

echo "== 10/11 simulation-service smoke (run -> kill -> resume -> verify parity) =="
python -m benchmarks.service_sim --smoke --no-write
python - <<'EOS'
import os, tempfile
from repro import api
from repro.sim import SimService

spec = api.ExperimentSpec(
    fleet=api.FleetSpec(n_nodes=4, hw=(8, 8), samples_per_node=40,
                        n_test=64, n_cloud_test=32),
    schedule=api.SchedulePolicy(kind="async"),
    train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
    rounds=3, seed=0)
base = api.run(api.compile_plan(spec))
svc = SimService(api.compile_plan(spec))
svc.run(max_records=1)                       # run ...
with tempfile.TemporaryDirectory() as d:
    path = svc.checkpoint(os.path.join(d, "ck"))
    del svc                                  # ... stop ...
    rep = SimService.resume(path).run()      # ... resume
recs = lambda r: [(x.t, x.version, x.accuracy, x.comm_bytes) for x in r.records]
assert recs(rep) == recs(base), "service resume parity violated"
assert rep.resume_round == 1
print("service kill/resume parity OK")
EOS

echo "== 11/11 fleet-health smoke (SLO probes + postmortem/diff rendering) =="
python -m benchmarks.health_smoke --smoke --no-write
python tools/bench_check.py
echo "CI OK"
