#!/usr/bin/env bash
# One-command smoke: module-import sweep + tier-1 pytest + a 2-round fleet
# run on synthetic data.
#
#   bash tools/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== 1/9 import sweep (every repro.* and benchmarks.* module) =="
python - <<'EOF'
import importlib
import pkgutil

import repro

failures = []
mods = ["repro"] + [m.name for m in
                    pkgutil.walk_packages(repro.__path__, "repro.")]
import benchmarks
mods += ["benchmarks"] + [m.name for m in
                          pkgutil.walk_packages(benchmarks.__path__,
                                                "benchmarks.")]
for name in mods:
    try:
        importlib.import_module(name)
    except Exception as e:  # noqa: BLE001 - report every broken module
        failures.append((name, repr(e)))
for name, err in failures:
    print(f"IMPORT FAILED: {name}: {err}")
print(f"imported {len(mods) - len(failures)}/{len(mods)} modules")
raise SystemExit(1 if failures else 0)
EOF

echo "== 2/9 tier-1 pytest =="
python -m pytest -q

echo "== 3/9 fleet smokes on synthetic data (2 sync rounds + 2 async windows) =="
python -m benchmarks.fleet_scale --smoke
python -m benchmarks.async_scale --smoke

echo "== 4/9 multi-device sharded fleet smoke (4 forced host devices) =="
python -m benchmarks.fleet_shard --smoke

echo "== 5/9 api smoke (spec -> plan -> run, every schedule x topology) =="
python -m benchmarks.api_smoke
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    python -m benchmarks.api_smoke --mesh 2

echo "== 6/9 network smoke (wire codecs + lossy-link run) =="
python -m benchmarks.net_sweep --smoke

echo "== 7/9 pallas fused-kernel smoke (megakernel + window-fold engines) =="
python -m benchmarks.api_smoke --backend pallas

echo "== 8/9 obs smoke (traced run + pinned benchmark baselines) =="
python -m benchmarks.obs_smoke
python tools/bench_check.py

echo "== 9/9 attack-matrix smoke (adversary zoo x defense x schedule) =="
python -m benchmarks.attack_matrix --smoke
echo "CI OK"
