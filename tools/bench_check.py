#!/usr/bin/env python
"""Check ``results/*.json`` trajectories against pinned baselines.

The benchmark trajectory files mix two kinds of columns: *deterministic*
outputs (byte counts, nnz, codec names, node counts, accuracies — fixed
by the seeds) and *timing* noise (wall seconds, speedups, timestamps).
This tool fingerprints each trajectory with the timing columns stripped
and diffs it against ``tools/bench_baselines.json``, so a refactor that
silently changes byte accounting, detection counts, or sweep coverage
fails CI even though every test still passes on fresh runs.

Usage:
  python tools/bench_check.py            # diff results/ vs the baselines
  python tools/bench_check.py --update   # re-pin baselines from results/
  python tools/bench_check.py --rtol 0.05 results/net_sweep.json

Exact match for ints/strings/bools; floats compare within ``--rtol``
(default 2% — accuracy columns jitter across BLAS builds, byte counts
are integers and stay exact).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(REPO, "tools", "bench_baselines.json")

# timing/noise columns: never part of the fingerprint
_NOISE = re.compile(
    r"^ts$|^wall_s$|^speedup$|s_per_(round|window|call)|^us_per_call$"
    r"|_wall_s$|^seq_estimated$|_us$")


def fingerprint(records):
    """The trajectory with noise columns dropped (order preserved)."""
    return [{k: v for k, v in sorted(rec.items()) if not _NOISE.search(k)}
            for rec in records]


def _close(a, b, rtol: float) -> bool:
    if isinstance(a, float) or isinstance(b, float):
        try:
            af, bf = float(a), float(b)
        except (TypeError, ValueError):
            return a == b
        return abs(af - bf) <= rtol * max(abs(af), abs(bf), 1e-12)
    return a == b


def _walk_diff(path, b, c, rtol, out):
    """Recursive per-key diff: every drift is reported as its own dotted/
    indexed leaf path (``name[3].net.codec: 'coo' -> 'bitpack'``), so a
    baseline re-pin is reviewable value by value instead of as one
    monolithic nested-blob mismatch."""
    if isinstance(b, dict) and isinstance(c, dict):
        for k in sorted(set(b) | set(c)):
            if k not in b:
                out.append(f"{path}.{k}: new column {c[k]!r}")
            elif k not in c:
                out.append(f"{path}.{k}: column dropped (was {b[k]!r})")
            else:
                _walk_diff(f"{path}.{k}", b[k], c[k], rtol, out)
    elif isinstance(b, list) and isinstance(c, list):
        if len(b) != len(c):
            out.append(f"{path}: length {len(b)} -> {len(c)}")
        for i, (bv, cv) in enumerate(zip(b, c)):
            _walk_diff(f"{path}[{i}]", bv, cv, rtol, out)
    elif not _close(b, c, rtol):
        out.append(f"{path}: {b!r} -> {c!r}")


def diff_one(name, base, cur, rtol):
    """Human-readable drift list between two fingerprints."""
    out = []
    if len(base) != len(cur):
        out.append(f"{name}: {len(base)} baseline records vs {len(cur)} "
                   f"current — sweep coverage changed")
    for i, (b, c) in enumerate(zip(base, cur)):
        _walk_diff(f"{name}[{i}]", b, c, rtol, out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="results files to check (default: results/*.json)")
    ap.add_argument("--update", action="store_true",
                    help="re-pin tools/bench_baselines.json from results/")
    ap.add_argument("--rtol", type=float, default=0.02,
                    help="relative tolerance for float columns")
    args = ap.parse_args(argv)

    files = args.files or sorted(glob.glob(os.path.join(REPO, "results",
                                                        "*.json")))
    current = {}
    for path in files:
        with open(path) as f:
            traj = json.load(f)
        if not isinstance(traj, list):
            print(f"bench_check: skipping {path} (not a trajectory list)")
            continue
        current[os.path.basename(path)] = fingerprint(traj)

    if args.update:
        with open(BASELINES, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"bench_check: pinned {len(current)} trajectories -> "
              f"{os.path.relpath(BASELINES, REPO)}")
        return 0

    if not os.path.exists(BASELINES):
        print("bench_check: no baselines pinned yet — run with --update")
        return 1
    with open(BASELINES) as f:
        base = json.load(f)

    drift = []
    for name, cur in sorted(current.items()):
        if name not in base:
            drift.append(f"{name}: no pinned baseline (run --update)")
            continue
        drift += diff_one(name, base[name], cur, args.rtol)
    for name in sorted(set(base) - set(current)):
        drift.append(f"{name}: pinned but missing from results/")

    if drift:
        print(f"bench_check: {len(drift)} drift(s) vs pinned baselines:")
        for d in drift:
            print(f"  {d}")
        print("(intentional? re-pin with: python tools/bench_check.py "
              "--update)")
        return 1
    print(f"bench_check: {len(current)} trajectories match the pinned "
          f"baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
