#!/usr/bin/env python
"""Render fleet postmortems and run-vs-run diffs from trace streams.

Trace-only input: both commands consume the events JSONL an
`ObsSpec(events_jsonl=...)` run streams (header + `TraceEvent` rows) and
never touch engine internals, so they work on live runs, crash
artifacts, and traces copied off other machines alike.

    # one run's story: incidents, stragglers, SLO compliance, detection
    python tools/obs_report.py postmortem /tmp/run/events.jsonl

    # two runs side by side, non-zero exit on regression (CI gate)
    python tools/obs_report.py diff base/events.jsonl cand/events.jsonl \
        --fail-on-regression
"""
import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.obs import read_jsonl  # noqa: E402
from repro.obs.report import postmortem_md, run_diff_md  # noqa: E402


def _load(path: str, strict: bool):
    try:
        return read_jsonl(path, strict=strict)
    except ValueError as e:
        raise SystemExit(f"obs_report: {e}\n(re-run with --tolerate-torn "
                         f"to drop a crash-torn final line)")


def _write(text: str, out):
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            f.write(text)
        print(f"wrote {out}")
    else:
        print(text, end="")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Markdown postmortems and run diffs from obs trace "
                    "streams (trace-only input)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    pm = sub.add_parser("postmortem",
                        help="render one run's Markdown postmortem")
    pm.add_argument("events", help="events JSONL from ObsSpec.events_jsonl")
    pm.add_argument("-o", "--out", default=None, help="output path "
                    "(default: stdout)")
    pm.add_argument("--top-k", type=int, default=5,
                    help="stragglers to list (default 5)")
    pm.add_argument("--tolerate-torn", action="store_true",
                    help="drop a crash-torn final JSONL line instead of "
                         "failing")

    df = sub.add_parser("diff", help="render a run-vs-run Markdown diff")
    df.add_argument("events_a", help="baseline events JSONL")
    df.add_argument("events_b", help="candidate events JSONL")
    df.add_argument("-o", "--out", default=None)
    df.add_argument("--rtol", type=float, default=0.05,
                    help="relative tolerance before a directional move "
                         "counts as a regression (default 0.05)")
    df.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 when any metric regresses (CI gate)")
    df.add_argument("--tolerate-torn", action="store_true")

    args = ap.parse_args(argv)
    strict = not args.tolerate_torn
    if args.cmd == "postmortem":
        rows = _load(args.events, strict)
        _write(postmortem_md(rows, top_k=args.top_k), args.out)
        return 0
    rows_a = _load(args.events_a, strict)
    rows_b = _load(args.events_b, strict)
    md, n_reg = run_diff_md(rows_a, rows_b,
                            label_a=os.path.basename(args.events_a),
                            label_b=os.path.basename(args.events_b),
                            rtol=args.rtol)
    _write(md, args.out)
    if n_reg and args.fail_on_regression:
        print(f"obs_report: {n_reg} regression(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
