"""Inject the generated §Dry-run / §Roofline tables into EXPERIMENTS.md."""
import io
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "tools")
import gen_tables  # noqa: E402


def capture(fn, recs):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(recs)
    return buf.getvalue()


def main():
    recs = gen_tables.load("results/dryrun")
    dry = capture(gen_tables.dryrun_table, recs)
    roof = capture(gen_tables.roofline_table, recs)
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dry)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("tables injected:", len(dry.splitlines()) - 2, "dry-run rows,",
          len(roof.splitlines()) - 2, "roofline rows")


if __name__ == "__main__":
    main()
