"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun."""
from __future__ import annotations

import glob
import json
import os
import sys

ARCH_ORDER = ["smollm-360m", "olmo-1b", "qwen1.5-0.5b", "codeqwen1.5-7b",
              "falcon-mamba-7b", "zamba2-1.2b", "whisper-large-v3",
              "qwen2-vl-72b", "llama4-scout-17b-a16e", "kimi-k2-1t-a32b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

HINTS = {
    ("collective_s", "moe"): "shard experts deeper / overlap a2a with expert einsum",
    ("collective_s", "dense"): "sequence-parallel reduce-scatter for the TP activation ARs",
    ("collective_s", "vlm"): "sequence-parallel reduce-scatter for the TP activation ARs",
    ("collective_s", "audio"): "sequence-parallel reduce-scatter for the TP activation ARs",
    ("collective_s", "ssm"): "batch-only sharding of scan states (avoid d_inner resharding)",
    ("collective_s", "hybrid"): "batch-only sharding of scan states",
    ("compute_s", "dense"): "flash-attention kernel + fp8 matmuls",
    ("compute_s", "moe"): "drop expert capacity factor / flash attention",
    ("memory_s", "ssm"): "fused Pallas scan (keep h in VMEM, never materialise h_all)",
    ("memory_s", "hybrid"): "fused SSD kernel; keep chunk states in VMEM",
    ("memory_s", "dense"): "Pallas flash attention (no score materialisation)",
    ("memory_s", "moe"): "Pallas flash attention; bf16 dispatch buffers",
    ("memory_s", "vlm"): "Pallas flash attention (no score materialisation)",
    ("memory_s", "audio"): "Pallas flash attention (no score materialisation)",
}

FAMILY = {"smollm-360m": "dense", "olmo-1b": "dense", "qwen1.5-0.5b": "dense",
          "codeqwen1.5-7b": "dense", "falcon-mamba-7b": "ssm",
          "zamba2-1.2b": "hybrid", "whisper-large-v3": "audio",
          "qwen2-vl-72b": "vlm", "llama4-scout-17b-a16e": "moe",
          "kimi-k2-1t-a32b": "moe"}


def load(results_dir):
    recs = {}
    for p in glob.glob(os.path.join(results_dir, "*.json")):
        d = json.load(open(p))
        recs[(d.get("arch"), d.get("shape"), d.get("mesh"))] = d
    return recs


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{digits}g}"


def dryrun_table(recs):
    print("| arch | shape | mesh | status | step | compile s | args GiB/dev |"
          " temp GiB/dev | AG | AR | A2A | CP |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for mesh in ("16x16", "2x16x16"):
        for a in ARCH_ORDER:
            for s in SHAPE_ORDER:
                d = recs.get((a, s, mesh))
                if d is None:
                    continue
                if d.get("status") != "ok":
                    print(f"| {a} | {s} | {mesh} | {d.get('status')} |  |  |  |  |  |  |  |  |")
                    continue
                m = d["memory"]
                n_dev = 512 if mesh == "2x16x16" else 256
                cc = d["collectives"]["count_by_type"]
                print(f"| {a} | {s} | {mesh} | ok | {d['step_kind']} "
                      f"| {d['timings']['compile_s']:.0f} "
                      f"| {m['argument_size_in_bytes']/n_dev/2**30:.2f} "
                      f"| {m['temp_size_in_bytes']/n_dev/2**30:.2f} "
                      f"| {int(cc.get('all-gather',0))} | {int(cc.get('all-reduce',0))} "
                      f"| {int(cc.get('all-to-all',0))} | {int(cc.get('collective-permute',0))} |")


def roofline_table(recs):
    print("| arch | shape | compute s | memory s (xla) | memory s (lb) |"
          " collective s | dominant (lb) | MODEL_FLOPS | useful ratio | lever for dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s, "16x16"))
            if d is None or d.get("status") != "ok":
                if d is not None:
                    print(f"| {a} | {s} | {d.get('status')} |  |  |  |  |  |  | {d.get('reason','')[:60]} |")
                continue
            r = d["roofline"]
            dom3 = {"compute_s": r["compute_s"], "memory_s": r["memory_lb_s"],
                    "collective_s": r["collective_s"]}
            dom = max(dom3, key=dom3.get)
            hint = HINTS.get((dom, FAMILY[a]), "")
            print(f"| {a} | {s} | {fmt(r['compute_s'])} | {fmt(r['memory_s'])} "
                  f"| {fmt(r['memory_lb_s'])} | {fmt(r['collective_s'])} "
                  f"| {dom.replace('_s','')} | {fmt(r['model_flops_global'])} "
                  f"| {r['useful_flops_ratio']} | {hint} |")


if __name__ == "__main__":
    recs = load(sys.argv[2] if len(sys.argv) > 2 else "results/dryrun")
    if sys.argv[1] == "dryrun":
        dryrun_table(recs)
    else:
        roofline_table(recs)
