"""Fleet demo: run a named scenario on the batched fleet engines.

Scenarios are declarative node populations (honest, label-flip adversaries,
stragglers, churn, sampled cohorts, private+sparse uploads, async variants)
— see `repro.fleet.scenarios.SCENARIOS`. `--engine sync` runs barrier
rounds on the cohort-batched `FleetEngine`; `--engine async` runs
virtual-time arrival windows on the `AsyncFleetEngine` (Eq. 6 mixing per
arrival, streaming detection).

  PYTHONPATH=src python examples/fleet_demo.py --scenario label_flip_20 \\
      --nodes 50 --rounds 8
  PYTHONPATH=src python examples/fleet_demo.py --engine async \\
      --scenario async_stragglers --nodes 30 --rounds 6

`--mesh D` shards the node axis over D local devices and runs the round /
window programs under shard_map (on a CPU-only host, fake the devices with
XLA_FLAGS=--xla_force_host_platform_device_count=D).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.fleet import (SCENARIOS, FleetMesh, build_async_engine,  # noqa: E402
                         build_engine, get_scenario)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="honest", choices=sorted(SCENARIOS))
    ap.add_argument("--engine", default="sync", choices=["sync", "async"])
    ap.add_argument("--nodes", type=int, default=0,
                    help="override the scenario's population size")
    ap.add_argument("--rounds", type=int, default=8,
                    help="sync rounds; async processes rounds*nodes arrivals")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--mesh", type=int, default=0, metavar="D",
                    help="shard the node axis over D local devices "
                         "(0 = single-device engines)")
    args = ap.parse_args()
    if args.nodes < 0 or args.rounds < 1:
        ap.error("--nodes must be >= 0 and --rounds >= 1")
    mesh = FleetMesh.create(args.mesh) if args.mesh else None

    sc = get_scenario(args.scenario)
    if args.nodes:
        sc = sc.with_nodes(args.nodes)
    print(f"scenario={sc.name} nodes={sc.n_nodes} model={sc.model} "
          f"sigma={sc.sigma} sparsify={sc.sparsify_ratio} "
          f"detect={sc.detect} engine={args.engine} backend={args.backend}"
          + (f" mesh={args.mesh}" if mesh else ""))

    if args.engine == "async":
        eng = build_async_engine(sc, seed=0, backend=args.backend, mesh=mesh)
        for rec in eng.run_arrivals(args.rounds * sc.n_nodes):
            print(f"  window={rec.window:3d} t={rec.t:8.2f}s "
                  f"acc={rec.accuracy:.3f} arrivals={rec.n_processed:4d} "
                  f"rejected={rec.n_rejected:3d} tau_max={rec.max_staleness:3d} "
                  f"bytes={rec.comm_bytes / 1e6:.2f}MB")
    else:
        eng = build_engine(sc, seed=0, backend=args.backend, mesh=mesh)
        for rec in eng.run(args.rounds):
            print(f"  round={rec.round:3d} t={rec.t:8.2f}s "
                  f"acc={rec.accuracy:.3f} participants={rec.n_participating:4d} "
                  f"rejected={rec.n_rejected:3d} "
                  f"bytes={rec.comm_bytes / 1e6:.2f}MB")
    print(f"final accuracy: {eng.history[-1].accuracy:.3f}")
    print(f"communication efficiency κ = {eng.kappa():.4f}")


if __name__ == "__main__":
    main()
