"""Quickstart: the paper's ALDPFL framework end-to-end in ~a minute on CPU.

Trains the paper's CNN (2 conv + 1 FC) across 10 simulated edge nodes
(3 label-flipping adversaries) with:
  * asynchronous α-mixing model updates (Eq. 6),
  * node-level LDP via clipped+noised deltas (Eq. 8, ε=8, δ=1e-3),
  * cloud-side top-s% malicious-node detection (Alg. 2, s=80).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.paper_cnn import config as paper_config
from repro.core import FedConfig, FederatedTrainer
from repro.data import make_federated_image_data
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn


def main() -> None:
    pc = paper_config()
    node_data, test, cloud, malicious = make_federated_image_data(
        seed=0, n_nodes=pc.n_nodes, n_malicious=pc.n_malicious,
        n_train=2000, n_test=500, n_cloud_test=300, hw=(14, 14),
        flip_src=pc.flip_src, flip_dst=pc.flip_dst)
    print(f"nodes={pc.n_nodes} (malicious: {malicious}), "
          f"attack: label {pc.flip_src} -> {pc.flip_dst}")

    # sigma=0.05 keeps a workable signal-to-noise ratio at this scale; the
    # paper's own ε=8 calibration (σ≈0.47) collapses accuracy to chance —
    # see EXPERIMENTS.md §Paper "honest finding" and `benchmarks/privacy_tradeoff`.
    cfg = FedConfig(mode="aldpfl", n_nodes=pc.n_nodes, rounds=6,
                    local_steps=15, batch_size=32, lr=0.1,
                    alpha=pc.alpha, epsilon=pc.epsilon, delta=pc.delta,
                    sigma=0.05, detect=True, detect_s=pc.detect_s)
    trainer = FederatedTrainer(
        init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)), cnn_loss,
        cnn_accuracy, node_data, test, cloud, cfg)

    print(f"LDP noise multiplier σ = {trainer.sigma:.4f} "
          f"(calibrated for ε={pc.epsilon}, δ={pc.delta})")
    for rec in trainer.run():
        print(f"  t={rec.t:7.2f}s  acc={rec.accuracy:.3f} "
              f"rejected={rec.n_rejected}")
    print(f"final accuracy: {trainer.history[-1].accuracy:.3f}")
    print(f"privacy spent:  ε = {trainer.epsilon_spent():.2f} "
          f"(δ = {cfg.delta})")
    print(f"communication efficiency κ = {trainer.kappa():.4f}")


if __name__ == "__main__":
    main()
