"""Quickstart: the paper's ALDPFL framework end-to-end in ~a minute on CPU.

Declares the experiment once through `repro.api` — population (10 edge
nodes, 3 label-flipping adversaries), schedule (asynchronous Eq. 6
α-mixing), privacy (node-level LDP, Eq. 8), defense (cloud-side top-s%
detection, Alg. 2 with s=80) — then compiles and runs it:

    spec -> compile_plan(spec) -> run(plan) -> RunReport

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api
from repro.configs.paper_cnn import config as paper_config


def main() -> None:
    pc = paper_config()
    # sigma=0.05 keeps a workable signal-to-noise ratio at this scale; the
    # paper's own ε=8 calibration (σ≈0.47) collapses accuracy to chance —
    # see EXPERIMENTS.md §Paper "honest finding" and `benchmarks/privacy_tradeoff`.
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(
            n_nodes=pc.n_nodes,
            attack=api.AttackMix(malicious_frac=pc.n_malicious / pc.n_nodes,
                                 flip_src=pc.flip_src, flip_dst=pc.flip_dst),
            model="cnn", hw=(14, 14), samples_per_node=200,
            n_test=500, n_cloud_test=300),
        schedule=api.SchedulePolicy(kind="async", alpha=pc.alpha),
        privacy=api.PrivacySpec(sigma=0.05, epsilon=pc.epsilon,
                                delta=pc.delta),
        defense=api.DefenseSpec(detect=True, detect_s=pc.detect_s),
        train=api.TrainSpec(local_steps=15, batch_size=32, lr=0.1),
        rounds=6, seed=0)

    plan = api.compile_plan(spec)
    print(f"nodes={pc.n_nodes} (malicious_frac="
          f"{spec.fleet.attack.malicious_frac}), "
          f"attack: label {pc.flip_src} -> {pc.flip_dst}")
    print(f"plan: {plan.describe()}")
    print(f"LDP noise multiplier σ = {plan.sigma:.4f}")

    report = api.run(plan)
    for rec in report.records:
        print(f"  t={rec.t:7.2f}s  acc={rec.accuracy:.3f} "
              f"rejected={rec.n_rejected}")
    print(f"final accuracy: {report.final_accuracy:.3f}")
    print(f"privacy spent:  ε = {report.epsilon_spent:.2f} "
          f"(δ = {spec.privacy.delta})")
    print(f"communication efficiency κ = {report.kappa:.4f}")

    # the whole result round-trips through JSON (schema-versioned), so it
    # can be archived next to the spec that produced it
    payload = report.to_json()
    assert api.RunReport.from_json(payload).records == report.records
    print(f"report JSON: {len(payload)} bytes, "
          f"schema v{report.schema_version}")


if __name__ == "__main__":
    main()
