"""Batched serving demo: prefill + KV-cache decode across architecture
families (dense GQA ring-cache, Mamba O(1) state, hybrid both) — with a
mid-generation checkpoint: the decode state (cache + last token) is saved
through `repro.checkpointing` halfway, reloaded, and the tail regenerated
to show the resumed continuation emits identical tokens.

  PYTHONPATH=src python examples/serve_demo.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.models import decode_step, init_cache, init_params, prefill


def serve(arch: str, batch=2, prompt=16, gen=8) -> None:
    cfg = get_smoke_config(arch).replace(attn_chunk=prompt)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt)),
                               jnp.int32)}
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cache = init_cache(cfg, batch, prompt + gen + extra, dtype=jnp.float32)
    jdec = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    logits, cache = jax.jit(lambda p, bb, c: prefill(p, cfg, bb, c))(
        params, b, cache)
    tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)

    def decode(tok, cache, steps):
        toks = []
        for _ in range(steps):
            logits, cache = jdec(params, tok, cache)
            tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
            toks.append(tok)
        return toks, tok, cache

    half = (gen - 1) // 2
    t0 = time.time()
    head, mid_tok, mid_cache = decode(tok, cache, half)
    # snapshot the decode state mid-generation: KV/SSM cache + last token
    ckpt = os.path.join(tempfile.mkdtemp(prefix="serve_"), arch)
    save_checkpoint(ckpt, {"cache": mid_cache, "tok": mid_tok}, step=half)
    tail, _, _ = decode(mid_tok, mid_cache, gen - 1 - half)
    dt = time.time() - t0
    out = jnp.concatenate([tok] + head + tail, 1)
    # resume: reload the snapshot and regenerate the tail — same tokens
    loaded, _ = load_checkpoint(ckpt, {"cache": mid_cache, "tok": mid_tok})
    tail2, _, _ = decode(loaded["tok"], loaded["cache"], gen - 1 - half)
    resumed = jnp.concatenate([tok] + head + tail2, 1)
    assert bool((out == resumed).all()), "resumed decode diverged"
    print(f"{arch:22s} [{cfg.family:6s}] decode {batch}x{gen-1} tokens "
          f"in {dt:5.2f}s -> {np.asarray(out[0, :8]).tolist()} "
          f"(resume parity ok)")


if __name__ == "__main__":
    for arch in ("smollm-360m", "falcon-mamba-7b", "zamba2-1.2b",
                 "kimi-k2-1t-a32b", "whisper-large-v3"):
        serve(arch)
