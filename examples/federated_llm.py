"""The paper's technique on a transformer LM — the datacenter fed_train_step.

One jitted SPMD program per federated round: per-node local SGD (scan) →
ALDP clip+noise (Eq. 8) → cloud-side detection (Alg. 2) → masked-mean
all-reduce + α-mix (Eq. 6). Runs the smoke variant of any assigned arch,
checkpoints the complete training state (model, PRNG chain, data stream)
halfway through `repro.checkpointing`, and replays the second half from
the checkpoint to show the resumed trajectory is bit-exact.

  PYTHONPATH=src python examples/federated_llm.py [--arch zamba2-1.2b]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import (load_checkpoint, read_manifest,
                                 save_checkpoint)
from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.fed_step import FedStepConfig, fed_train_step
from repro.data.synthetic import make_token_dataset
from repro.models import init_params, loss_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(attn_chunk=16)
    fcfg = FedStepConfig(n_nodes=4, local_steps=2, lr=0.1, alpha=0.5,
                         sigma=1e-3, clip_s=1.0, detect=True, detect_s=50.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name}  params={n_params/1e6:.2f}M  "
          f"nodes={fcfg.n_nodes}  local_steps={fcfg.local_steps}  "
          f"σ={fcfg.sigma}  s={fcfg.detect_s}")

    seq = 32
    data = make_token_dataset(0, 256, seq, cfg.vocab)
    rng = np.random.default_rng(0)

    def batch(lead, cfg=cfg):
        n = int(np.prod(lead))
        idx = rng.integers(0, data.shape[0], n)
        b = {"tokens": jnp.asarray(data[idx, :seq].reshape(lead + (seq,))),
             "targets": jnp.asarray(data[idx, 1:seq + 1].reshape(lead + (seq,)))}
        if cfg.family == "vlm":
            b["patches"] = jnp.asarray(rng.normal(
                0, 1, lead + (cfg.n_patches, cfg.d_model)), jnp.float32)
        if cfg.family == "audio":
            b["frames"] = jnp.asarray(rng.normal(
                0, 1, lead + (cfg.n_audio_frames, cfg.d_model)), jnp.float32)
        return b

    lfn = lambda p, b: loss_fn(p, cfg, b)
    afn = lambda p, b: loss_fn(p, cfg, b)[1]["accuracy"]
    step = jax.jit(lambda p, nb, eb, k: fed_train_step(
        p, nb, eb, k, loss_fn=lfn, acc_fn=afn, fcfg=fcfg))

    def train(params, key, start, stop, tag=""):
        for r in range(start, stop):
            key, k = jax.random.split(key)
            nb = batch((fcfg.n_nodes, fcfg.local_steps, 2))
            eb = batch((2,))
            params, m = step(params, nb, eb, k)
            print(f"{tag}round {r:2d}  loss={float(m['loss']):.4f}  "
                  f"node_acc={float(m['node_accuracies'].mean()):.3f}  "
                  f"normal={int(m['n_normal'])}/{fcfg.n_nodes}  "
                  f"Δ-norm={float(m['delta_norm_mean']):.3f}", flush=True)
        return params, key

    key = jax.random.PRNGKey(1)
    half = max(1, args.rounds // 2)
    params, key = train(params, key, 0, half)

    # checkpoint the complete training state at the round boundary: model,
    # PRNG chain key, and the host data stream's RNG position
    ckpt = os.path.join(tempfile.mkdtemp(prefix="fed_llm_"), "ck")
    save_checkpoint(ckpt, {"params": params, "key": key}, step=half,
                    extra={"data_rng": rng.bit_generator.state})
    ck_params, ck_key = params, key
    print(f"checkpointed round {half} -> {ckpt}.npz")
    params_full, _ = train(params, key, half, args.rounds)

    # kill-and-resume: reload the checkpoint, rewind the data stream, and
    # replay the second half — the final model must match bit for bit
    loaded, start = load_checkpoint(ckpt, {"params": ck_params,
                                           "key": ck_key})
    rng.bit_generator.state = read_manifest(ckpt)["extra"]["data_rng"]
    params_resumed, _ = train(loaded["params"], loaded["key"], start,
                              args.rounds, tag="resume ")
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(params_full),
                   jax.tree.leaves(params_resumed)))
    assert diff == 0.0, f"resumed trajectory diverged: max |Δ| = {diff}"
    print(f"resume parity: rounds {half}..{args.rounds} replayed "
          f"bit-exactly (max |Δ| = {diff})")


if __name__ == "__main__":
    main()
