"""Attack vs defence demo: both attacks from the paper, and both defences.

1. Label-flipping (poisoning): 30% malicious nodes flip class 1 -> 7; compare
   ALDPFL accuracy with and without the cloud-side detection mechanism.
2. Gradient leakage (DLG): a malicious cloud reconstructs a node's input from
   its gradients; the ALDP noise breaks the reconstruction.

  PYTHONPATH=src python examples/attack_defense.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import FedConfig, FederatedTrainer
from repro.core.aldp import add_gaussian_noise
from repro.core.attacks import dlg_attack, reconstruction_mse
from repro.data import make_federated_image_data
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn, per_class_accuracy


def label_flip_experiment() -> None:
    print("=== 1. label-flipping attack (p=30%) ===")
    node_data, test, cloud, _ = make_federated_image_data(
        seed=0, n_nodes=10, n_malicious=3, n_train=1500, n_test=400,
        n_cloud_test=300, hw=(14, 14))
    for detect in (False, True):
        cfg = FedConfig(mode="aldpfl", n_nodes=10, rounds=4, local_steps=12,
                        batch_size=32, lr=0.1, detect=detect, sigma=0.05)
        tr = FederatedTrainer(init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)),
                              cnn_loss, cnn_accuracy, node_data, test, cloud,
                              cfg)
        hist = tr.run()
        special = float(per_class_accuracy(tr.params, *tr.test_data, 1))
        print(f"  detection={'ON ' if detect else 'OFF'}  "
              f"general acc={hist[-1].accuracy:.3f}  "
              f"class-1 acc={special:.3f}  "
              f"rejected={sum(r.n_rejected for r in hist)} updates")


def dlg_experiment() -> None:
    print("=== 2. gradient-leakage (DLG) attack vs ALDP ===")
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (64, 10)) * 0.2

    def loss(params, x, y_soft):
        return jnp.mean((x @ params - y_soft) ** 2)

    # two samples: the rank-2 gradient pins the reconstruction scale
    x_true = jax.random.normal(jax.random.PRNGKey(1), (2, 64)) * 0.5
    y_true = jax.nn.one_hot(jnp.array([3, 7]), 10)
    g = jax.grad(loss)(W, x_true, y_true)
    for sigma in (0.0, 0.1, 0.5):
        g_obs = g if sigma == 0 else add_gaussian_noise(
            g, jax.random.PRNGKey(2), sigma, 1.0)
        x_rec, _ = dlg_attack(loss, W, g_obs, (2, 64), 10,
                              jax.random.PRNGKey(3), steps=400, lr=0.1)
        mse = float(reconstruction_mse(x_true, x_rec))
        verdict = "LEAKED" if mse < 0.05 else "protected"
        print(f"  σ={sigma:4.2f}: reconstruction MSE={mse:8.4f}  -> {verdict}")


if __name__ == "__main__":
    label_flip_experiment()
    dlg_experiment()
