"""Attack vs defence demo: both attacks from the paper, and both defences.

1. Label-flipping (poisoning): 30% malicious nodes flip class 1 -> 7; compare
   ALDPFL accuracy with and without the cloud-side detection mechanism.
2. Gradient leakage (DLG): a malicious cloud reconstructs a node's input from
   its gradients; the ALDP noise breaks the reconstruction.

  PYTHONPATH=src python examples/attack_defense.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import api
from repro.core.aldp import add_gaussian_noise
from repro.core.attacks import dlg_attack, reconstruction_mse
from repro.models.cnn import per_class_accuracy


def label_flip_experiment() -> None:
    print("=== 1. label-flipping attack (p=30%) ===")
    for detect in (False, True):
        spec = api.ExperimentSpec(
            fleet=api.FleetSpec(n_nodes=10,
                                attack=api.AttackMix(malicious_frac=0.3),
                                model="cnn", hw=(14, 14),
                                samples_per_node=150, n_test=400,
                                n_cloud_test=300),
            schedule=api.SchedulePolicy(kind="async"),
            privacy=api.PrivacySpec(sigma=0.05),
            defense=api.DefenseSpec(detect=detect),
            train=api.TrainSpec(local_steps=12, batch_size=32, lr=0.1),
            rounds=4, seed=0)
        plan = api.compile_plan(spec)
        pop = api.materialize(spec)
        rep = api.run(plan, population=pop)
        special = float(per_class_accuracy(rep.final_params,
                                           *pop.test_data, 1))
        print(f"  detection={'ON ' if detect else 'OFF'}  "
              f"general acc={rep.final_accuracy:.3f}  "
              f"class-1 acc={special:.3f}  "
              f"rejected={sum(r.n_rejected for r in rep.records)} updates")


def dlg_experiment() -> None:
    print("=== 2. gradient-leakage (DLG) attack vs ALDP ===")
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (64, 10)) * 0.2

    def loss(params, x, y_soft):
        return jnp.mean((x @ params - y_soft) ** 2)

    # two samples: the rank-2 gradient pins the reconstruction scale
    x_true = jax.random.normal(jax.random.PRNGKey(1), (2, 64)) * 0.5
    y_true = jax.nn.one_hot(jnp.array([3, 7]), 10)
    g = jax.grad(loss)(W, x_true, y_true)
    for sigma in (0.0, 0.1, 0.5):
        g_obs = g if sigma == 0 else add_gaussian_noise(
            g, jax.random.PRNGKey(2), sigma, 1.0)
        x_rec, _ = dlg_attack(loss, W, g_obs, (2, 64), 10,
                              jax.random.PRNGKey(3), steps=400, lr=0.1)
        mse = float(reconstruction_mse(x_true, x_rec))
        verdict = "LEAKED" if mse < 0.05 else "protected"
        print(f"  σ={sigma:4.2f}: reconstruction MSE={mse:8.4f}  -> {verdict}")


if __name__ == "__main__":
    label_flip_experiment()
    dlg_experiment()
